"""Online calibrator: folds measured lane timings into perf-model fits.

Executors attached to a Calibrator (``Executor(..., calibrator=...)``)
push one sample per measured lane — from traced runs and from
``time_lanes`` sweeps alike — as ``(feature row, kind, seconds)``.
The feature row is the lane's summed unit-coefficient model terms
(:func:`repro.core.perf_model.lane_feature_rows`), which depend only on
the plan and the base HW rate constants, NOT on the calibrated
multipliers — so samples taken under different calibration generations
remain mutually consistent and accumulate evidence across retunes.

``fit`` delegates to :func:`repro.core.perf_model.fit_terms`, which
guards against underdetermined systems (min samples per pipeline class,
regularised toward the prior, residual check) and returns the fit
diagnostics alongside the calibrated HW.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Dict, Optional, Sequence

import numpy as np

from ..core import perf_model

__all__ = ["Calibrator", "CalibrationFit"]


@dataclasses.dataclass
class CalibrationFit:
    hw: perf_model.HW
    diag: Dict[str, Any]

    @property
    def ok(self) -> bool:
        return self.diag.get("fallback") is None


class Calibrator:
    """Thread-safe bounded ring of lane calibration samples + guarded fit.

    ``window`` bounds memory; ``min_per_class`` / ``min_samples`` gate
    when a fit is even attempted (and are re-checked inside ``fit_terms``
    per design-matrix column).
    """

    def __init__(self, window: int = 2048, min_samples: int = 6,
                 min_per_class: int = 3, max_cond: float = 1e8,
                 max_residual: float = 0.75):
        self._lock = threading.Lock()
        self._samples: deque = deque(maxlen=int(window))
        self.min_samples = int(min_samples)
        self.min_per_class = int(min_per_class)
        self.max_cond = float(max_cond)
        self.max_residual = float(max_residual)
        self._n_total = 0   # lifetime count (ring may have evicted)

    def add_lane(self, row: Sequence[float], kind: str,
                 measured_s: float) -> None:
        measured_s = float(measured_s)
        if measured_s <= 0.0:
            return
        row = np.asarray(row, dtype=np.float64)
        with self._lock:
            self._samples.append((row, str(kind), measured_s))
            self._n_total += 1

    def counts(self) -> Dict[str, int]:
        """Sample counts: total in window, and per pipeline class (a
        mixed lane counts toward both classes — its row has both edge
        columns populated)."""
        with self._lock:
            rows = list(self._samples)
        little = sum(1 for r, _, _ in rows if r[0] > 0.0)
        big = sum(1 for r, _, _ in rows if r[1] > 0.0)
        return {"n": len(rows), "n_total": self._n_total,
                "little": little, "big": big}

    def ready(self) -> bool:
        c = self.counts()
        if c["n"] < self.min_samples:
            return False
        return (c["little"] >= self.min_per_class
                or c["big"] >= self.min_per_class)

    def fit(self, prior_hw: perf_model.HW) -> Optional[CalibrationFit]:
        """Fit calibrated multipliers against the window; returns None
        when there is nothing to fit yet. The returned fit may still be
        a guarded fallback (``fit.ok`` False) when the system was
        underdetermined or the residual too large — the caller decides
        whether a fallback is worth acting on."""
        with self._lock:
            samples = list(self._samples)
        if len(samples) < self.min_samples:
            return None
        rows = [r for r, _, _ in samples]
        ys = [y for _, _, y in samples]
        hw, diag = perf_model.fit_terms(
            rows, ys, prior_hw, min_per_class=self.min_per_class,
            max_cond=self.max_cond, max_residual=self.max_residual)
        return CalibrationFit(hw=hw, diag=diag)

    def clear(self) -> None:
        with self._lock:
            self._samples.clear()
