"""Retuner: drift-triggered recalibration and adaptive re-planning.

The :class:`AutoTuner` closes the model-guided loop from ROADMAP item 1.
It owns a clearable :class:`~repro.obs.drift.DriftAccumulator` spliced
ABOVE the service-level one (``metrics.drift.set_parent(tuner.drift)``),
so every measured sample — per-lane from traced runs and ``time_lanes``
sweeps, per-iteration makespans from every run — flows into its window.
When the per-kind ``ratio_p50`` crosses the policy threshold (with
hysteresis after a retune, plus a cooldown), the tuner:

1. runs a ``time_lanes`` calibration sweep (feeding the Calibrator),
2. fits new HW multipliers (:meth:`Calibrator.fit`, guarded),
3. re-derives the plan under the new HW: ``classify()`` re-runs inside
   ``Planner.build`` for every candidate ``PlanConfig`` (model mode plus
   the fixed M:N sweep), each scored by its LPT ``est_makespan``,
4. atomically publishes the winner: the rebuilt bundle is inserted into
   the store's plan LRU under its quantized-HW cache key BEFORE the
   tuner's current HW flips, so a submit that races the retune either
   sees the old (config, plan) pair or the new one — never a mix,
5. persists the calibrated spec to the :class:`~.specs.SpecRegistry`
   with a bumped version.

In-flight executors keep their old plans (bit-identical results either
way); new submits resolve through :meth:`AutoTuner.resolve_config` and
pick up the calibrated HW + best split.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core import perf_model
from ..core.planner import PlanConfig, Planner
from ..core.types import Geometry
from ..obs.drift import DriftAccumulator
from .calibrator import Calibrator
from .specs import DeviceSpec, SpecRegistry, default_device_kind, geometry_key

__all__ = ["RetunePolicy", "AutoTuner", "candidate_configs", "search_plan"]


@dataclasses.dataclass
class RetunePolicy:
    """When to trip a retune.

    A kind trips when its windowed ``ratio_p50`` (measured/estimated)
    leaves ``[1/drift_threshold, drift_threshold]`` with at least
    ``min_samples`` ratio samples. After a retune the effective
    threshold is widened by ``hysteresis`` until drift is observed back
    inside the base band once (re-arming), and no retune fires within
    ``cooldown_s`` of the previous one.
    """

    drift_threshold: float = 1.5
    min_samples: int = 8
    cooldown_s: float = 30.0
    hysteresis: float = 1.3
    kinds: Tuple[str, ...] = ("little", "big", "mixed", "makespan")

    def __post_init__(self):
        if self.drift_threshold <= 1.0:
            raise ValueError("drift_threshold must be > 1")
        if self.hysteresis < 1.0:
            raise ValueError("hysteresis must be >= 1")


def _worst_kind(report: Dict[str, Dict[str, Any]], kinds, threshold: float,
                min_samples: int) -> Optional[Tuple[str, float]]:
    """The kind whose p50 drift ratio is furthest outside the band, or
    None if every (sufficiently sampled) kind is inside."""
    worst = None
    for kind in kinds:
        entry = report.get(kind)
        if not entry or entry.get("n", 0) < min_samples:
            continue
        r = entry.get("ratio_p50", entry.get("ratio"))
        if not r or r <= 0:
            continue
        sev = max(r, 1.0 / r)   # symmetric: 2x slow == 2x fast
        if sev > threshold and (worst is None or sev > worst[1]):
            worst = (kind, sev)
    return worst


def candidate_configs(base: PlanConfig, hw: perf_model.HW,
                      include_monolithic: bool = False) -> List[PlanConfig]:
    """The retune search space: model mode plus the fixed M:N lane-split
    sweep (paper Fig. 10) under the freshly calibrated HW. Interior
    fixed splits keep the model classification (only lane allocation is
    forced), so their blockings are shared with the model candidate and
    scoring them is cheap. The monolithic baseline re-blocks everything
    through Big and is opt-in."""
    n = base.n_lanes
    cands = [PlanConfig(mode="model", n_lanes=n, hw=hw)]
    for m in range(1, n):
        cands.append(PlanConfig(mode="fixed", forced_little=m,
                                forced_big=n - m, n_lanes=n, hw=hw))
    if include_monolithic:
        cands.append(PlanConfig(mode="monolithic", n_lanes=n, hw=hw))
    return cands


def search_plan(store, base: PlanConfig, hw: perf_model.HW,
                include_monolithic: bool = False):
    """Score every candidate by its LPT plan's ``est_makespan`` (built
    via Planner directly — losers never pollute the store's plan LRU)
    and return ``(best_config, best_bundle, scores)``."""
    best = None
    scores: List[Dict[str, Any]] = []
    for cfg in candidate_configs(base, hw, include_monolithic):
        bundle = Planner(store, cfg).build()
        est = float(bundle.plan.est_makespan)
        scores.append({"mode": cfg.mode,
                       "split": f"{cfg.forced_little}:{cfg.forced_big}"
                       if cfg.mode == "fixed" else None,
                       "est_makespan": est})
        if best is None or est < best[2]:
            best = (cfg, bundle, est)
    assert best is not None
    return best[0], best[1], scores


class AutoTuner:
    """Drift-watching calibrate-and-replan policy for a GraphService.

    ``registry=None`` uses the default :class:`SpecRegistry` (persist
    specs across processes); ``registry=False`` disables persistence.
    """

    def __init__(self, policy: Optional[RetunePolicy] = None,
                 calibrator: Optional[Calibrator] = None,
                 registry=None, device_kind: Optional[str] = None,
                 sweep_repeats: int = 3, time_repeats: int = 2,
                 include_monolithic: bool = False,
                 max_events: int = 64):
        self.policy = policy or RetunePolicy()
        self.calibrator = calibrator or Calibrator()
        self.registry: Optional[SpecRegistry]
        if registry is False:
            self.registry = None
        else:
            self.registry = registry or SpecRegistry()
        self.device_kind = device_kind or default_device_kind()
        self.sweep_repeats = int(sweep_repeats)      # time_lanes calls
        self.time_repeats = int(time_repeats)        # repeats per call
        self.include_monolithic = bool(include_monolithic)
        # the tuner-scope drift window (cleared at each retune); splice
        # with metrics.drift.set_parent(self.drift)
        self.drift = DriftAccumulator()
        self.hw: Optional[perf_model.HW] = None      # current calibrated HW
        self.version = 0
        self.calibrated_at: Optional[float] = None
        self.retunes = 0
        self.fit_rejects = 0
        self.events: List[Dict[str, Any]] = []
        self._max_events = int(max_events)
        self._best_cfg: Dict[Any, PlanConfig] = {}   # per graph skey
        self._lock = threading.RLock()
        self._last_retune_mono = -math.inf
        self._armed = True

    # -- startup ------------------------------------------------------
    def load(self, geom: Geometry) -> Optional[DeviceSpec]:
        """Adopt the persisted spec for (device kind, geom), if any.
        Returns the spec when one was adopted."""
        if self.registry is None:
            return None
        spec = self.registry.get(self.device_kind, geom)
        if spec is None or spec.source == "analytic":
            return None
        with self._lock:
            self.hw = spec.hw
            self.version = spec.version
            self.calibrated_at = spec.created_at
        return spec

    # -- submit-path hook ---------------------------------------------
    def resolve_config(self, config: PlanConfig,
                       skey=None) -> PlanConfig:
        """Rewrite a default-shaped config to the current calibrated HW
        (and, in model mode, to the last search winner for this graph).
        Configs carrying an explicit user HW (anything that is not the
        ``perf_model.TPU_V5E`` module singleton) pass through untouched —
        autotuning never overrides a caller's model."""
        if config.hw is not perf_model.TPU_V5E:
            return config
        with self._lock:
            if self.hw is None:
                return config
            best = self._best_cfg.get(skey) if skey is not None else None
            if (best is not None and config.mode == "model"
                    and best.n_lanes == config.n_lanes
                    and best.hw is self.hw):
                return best
            return dataclasses.replace(config, hw=self.hw)

    # -- drift policy -------------------------------------------------
    def _trip(self) -> Optional[Tuple[str, float]]:
        """Policy check against the tuner's own drift window. Handles
        re-arming: after a retune the band widens by ``hysteresis``
        until drift is observed back inside the base band."""
        pol = self.policy
        report = self.drift.report()
        base = _worst_kind(report, pol.kinds, pol.drift_threshold,
                           pol.min_samples)
        with self._lock:
            if not self._armed:
                if base is None and any(
                        report.get(k, {}).get("n", 0) >= pol.min_samples
                        for k in pol.kinds):
                    self._armed = True    # back in band: re-arm
                else:
                    wide = pol.drift_threshold * pol.hysteresis
                    return _worst_kind(report, pol.kinds, wide,
                                       pol.min_samples)
            return base

    def _cooldown_ok(self) -> bool:
        return (time.monotonic() - self._last_retune_mono
                >= self.policy.cooldown_s)

    def should_retune(self) -> Optional[Tuple[str, float]]:
        """(kind, severity) when policy + cooldown say retune now."""
        trip = self._trip()
        if trip is None or not self._cooldown_ok():
            return None
        return trip

    # -- the retune itself --------------------------------------------
    def observe(self, store, executor, config: PlanConfig,
                skey=None) -> Optional[Dict[str, Any]]:
        """Post-execution hook: retune iff the policy trips. Non-blocking
        under contention — a concurrent retune makes this a no-op."""
        trip = self.should_retune()
        if trip is None:
            return None
        if not self._lock.acquire(blocking=False):
            return None
        try:
            if self.should_retune() is None:   # raced: someone retuned
                return None
            return self.retune(store, executor, config, skey=skey,
                               reason={"kind": trip[0],
                                       "severity": trip[1]})
        finally:
            self._lock.release()

    def retune(self, store, executor, config: PlanConfig, skey=None,
               reason: Optional[Dict[str, Any]] = None,
               force: bool = False) -> Dict[str, Any]:
        """Calibration sweep -> guarded fit -> candidate search -> atomic
        plan swap -> spec persist. Returns an event dict (also appended
        to ``self.events``); ``event["applied"]`` tells whether a new
        calibration took effect."""
        with self._lock:
            t0 = time.perf_counter()
            event: Dict[str, Any] = {
                "reason": reason or ({"kind": "manual"} if force
                                     else {"kind": "unknown"}),
                "applied": False,
            }
            # 1. calibration sweep — executor feeds self.calibrator.
            # Adaptive: small plans have few lanes, so keep sweeping
            # (bounded) until the calibrator can even attempt a fit.
            max_sweeps = max(self.sweep_repeats, 2 * self.calibrator.min_samples)
            for i in range(max_sweeps):
                executor.time_lanes(repeats=self.time_repeats)
                if (i + 1 >= self.sweep_repeats
                        and self.calibrator.counts()["n"]
                        >= self.calibrator.min_samples):
                    break
            # 2. guarded fit (prior = current calibrated HW, else the
            # bundle's — both carry the same base rate constants)
            prior = self.hw or executor.bundle.config.hw
            fit = self.calibrator.fit(prior)
            self._last_retune_mono = time.monotonic()
            if fit is None or not fit.ok:
                self.fit_rejects += 1
                event["fit"] = fit.diag if fit is not None else None
                event["rejected"] = ("no_fit" if fit is None
                                     else fit.diag.get("fallback"))
                self._push_event(event)
                return event
            new_hw = fit.hw
            event["fit"] = fit.diag
            # 3. candidate search under the new HW
            best_cfg, best_bundle, scores = search_plan(
                store, config, new_hw,
                include_monolithic=self.include_monolithic)
            event["candidates"] = scores
            event["chosen"] = {"mode": best_cfg.mode,
                               "split": (f"{best_cfg.forced_little}:"
                                         f"{best_cfg.forced_big}"
                                         if best_cfg.mode == "fixed"
                                         else None),
                               "est_makespan":
                                   float(best_bundle.plan.est_makespan)}
            # 4. atomic swap: cache the rebuilt bundle FIRST, then flip
            # the tuner's HW — racing submits see old or new, never torn
            store.adopt_plan(best_bundle)
            self.hw = new_hw
            self.version += 1
            self.calibrated_at = time.time()
            if skey is not None:
                self._best_cfg[skey] = best_cfg
            self.retunes += 1
            self._armed = False          # hysteresis until back in band
            self.drift.clear()           # judge the NEW model from zero
            # 5. persist the spec
            if self.registry is not None:
                try:
                    spec = DeviceSpec(
                        device_kind=self.device_kind,
                        geom_key=geometry_key(store.geom),
                        hw=new_hw, version=self.version,
                        created_at=self.calibrated_at,
                        source="calibrated", fit=fit.diag)
                    event["spec_path"] = self.registry.put(spec)
                except OSError:
                    event["spec_path"] = None   # persistence is advisory
            event["applied"] = True
            event["t_retune_s"] = time.perf_counter() - t0
            self._push_event(event)
            return event

    def _push_event(self, event: Dict[str, Any]) -> None:
        self.events.append(event)
        if len(self.events) > self._max_events:
            del self.events[:len(self.events) - self._max_events]

    # -- introspection ------------------------------------------------
    def calibration_info(self) -> Dict[str, Any]:
        """Small dict for metrics: version / age / retune counters."""
        with self._lock:
            age = (time.time() - self.calibrated_at
                   if self.calibrated_at else None)
            return {"version": self.version, "age_s": age,
                    "retunes": self.retunes,
                    "fit_rejects": self.fit_rejects}

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            info = {
                "device_kind": self.device_kind,
                "version": self.version,
                "calibrated_at": self.calibrated_at,
                "retunes": self.retunes,
                "fit_rejects": self.fit_rejects,
                "armed": self._armed,
                "policy": dataclasses.asdict(self.policy),
                "samples": self.calibrator.counts(),
                "drift": self.drift.report(),
                "events": list(self.events[-8:]),
            }
            if self.hw is not None:
                info["hw"] = {
                    "c_edges": self.hw.c_edges,
                    "c_edges_big": self.hw.c_edges_big,
                    "c_vertices": self.hw.c_vertices,
                    "c_compute": self.hw.c_compute,
                    "c_store": self.hw.c_store,
                    "t_const": self.hw.t_const,
                    "combine": self.hw.combine,
                }
            return info
