"""Plan-aware lane→device placement (the sharding layer's brain).

ReGraph's scaling argument — many lightweight specialized pipelines,
each owning its own memory channels — applies one level up: one DEVICE
per lane group, edges fully sharded. The packed lane payload (one
contiguous device payload per (lane, kind), see ``kernels.ops``) is the
natural shard unit: lanes are tile-disjoint by construction, so devices
never write the same output tile and the cross-device merge is a single
``psum``/``pmin``/``pmax`` per iteration.

Placement is LPT (longest-processing-time-first) over the perf model's
per-lane time estimates — the same greedy the intra-cluster scheduler
uses to pack entries onto lanes — run in TWO kind-grouped passes over a
SHARED load vector: Little lanes first, then Big lanes. Because each
pass assigns to the least-loaded device, devices that received more
Little work receive less Big work, so both pipeline types interleave
across devices and stay busy (GraphScale/ScalaBFS: multi-channel
scaling lives or dies on partition-to-channel placement).

Greedy min-load assignment guarantees the classical bound

    max_load  <=  total_est / n_devices + max_lane_est

regardless of arrival order (``tests/test_sharding.py`` holds this as a
property over random graphs), so a fresh placement can never be
pathologically skewed. Streaming re-placement passes ``keep=`` — the
owners of clean (signature-matched, dirty-partition-free) lanes — and
only the remaining lanes are re-placed; kept lanes' resident device
payloads are then reused without re-transfer (see
``repro.streaming.apply_delta`` and ``PlanBundle.sharded_lanes``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["LanePlacement", "lane_estimates", "place_lanes"]


def lane_estimates(plan) -> List[float]:
    """Modelled execution time of each lane: the sum of its entries'
    ``est_time`` (the equal-time splits the scheduler packed). Pure
    plan-derived — no device or payload needed."""
    return [float(sum(e.est_time for e in lane)) for lane in plan.lanes]


@dataclasses.dataclass(frozen=True)
class LanePlacement:
    """Immutable lane→device assignment plus its load accounting.

    Attributes
    ----------
    n_devices:       number of devices placed onto.
    num_little_lanes: the plan's M (lanes [0, M) are Little, [M, M+N) Big).
    device_of_lane:  owner device index per lane.
    lane_ests:       per-lane modelled times the placement balanced.

    Invariants: every lane has exactly one owner in ``[0, n_devices)``;
    fresh (keep-free) placements satisfy the greedy bound
    ``max(loads) <= sum(lane_ests)/n_devices + max(lane_ests)``.
    """

    n_devices: int
    num_little_lanes: int
    device_of_lane: Tuple[int, ...]
    lane_ests: Tuple[float, ...]

    def lanes_of(self, device: int) -> List[int]:
        """Lane indices owned by one device (ascending — Little lanes,
        being lower-indexed, come first: the interleaved queue order)."""
        return [i for i, d in enumerate(self.device_of_lane) if d == device]

    @property
    def loads(self) -> Tuple[float, ...]:
        """Per-device summed lane estimates (the balanced quantity)."""
        out = [0.0] * self.n_devices
        for i, d in enumerate(self.device_of_lane):
            out[d] += self.lane_ests[i]
        return tuple(out)

    @property
    def imbalance(self) -> float:
        """max/mean device load; 1.0 is perfect balance (and the value
        reported for an all-empty plan)."""
        loads = self.loads
        mean = sum(loads) / max(len(loads), 1)
        if mean <= 0.0:
            return 1.0
        return max(loads) / mean

    def lpt_bound(self) -> float:
        """The greedy guarantee: ``total/n + max_est``. Fresh placements
        never exceed it (property-tested); streaming re-placements with
        ``keep=`` may, by design — they trade balance for residency."""
        total = sum(self.lane_ests)
        return total / max(self.n_devices, 1) + max(self.lane_ests,
                                                    default=0.0)

    def needs_rebalance(self, threshold: float) -> bool:
        """Placement-drift trigger: True when the measured imbalance
        (max/mean load) exceeds ``threshold``. Across a delta chain,
        ``keep=``-pinned re-placements accumulate skew a fresh LPT
        would not have; the streaming layer uses this to decide when to
        drop the pins and re-place from scratch (see
        ``repro.streaming.rebuild_plans``)."""
        return self.imbalance > float(threshold)

    def stats(self) -> dict:
        loads = self.loads
        return {
            "n_devices": self.n_devices,
            "lanes_per_device": [len(self.lanes_of(d))
                                 for d in range(self.n_devices)],
            "est_loads": list(loads),
            "imbalance": self.imbalance,
            "lpt_bound": self.lpt_bound(),
        }


def place_lanes(plan, n_devices: int,
                keep: Optional[Dict[int, int]] = None,
                lane_ests: Optional[Sequence[float]] = None
                ) -> LanePlacement:
    """LPT-place a plan's lanes onto ``n_devices`` devices.

    Parameters
    ----------
    plan:      a :class:`~repro.core.types.SchedulePlan`.
    n_devices: target device count (>= 1). More devices than lanes is
               legal — the surplus devices simply receive no work.
    keep:      lane index -> device index assignments to preserve
               verbatim (streaming re-placement: clean lanes stay where
               their payloads are resident). Kept loads are charged
               before any free lane is placed.
    lane_ests: override per-lane estimates (defaults to
               :func:`lane_estimates`).

    Returns a :class:`LanePlacement`. Deterministic: ties in both the
    size ordering (stable sort on (-est, lane index)) and the min-load
    argmin (lowest device index) are broken by index.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    ests = list(lane_ests) if lane_ests is not None else lane_estimates(plan)
    n_lanes = len(plan.lanes)
    if len(ests) != n_lanes:
        raise ValueError(f"lane_ests has {len(ests)} entries for "
                         f"{n_lanes} lanes")
    keep = dict(keep or {})
    owner = [-1] * n_lanes
    loads = np.zeros(n_devices)
    for i, d in keep.items():
        if not (0 <= i < n_lanes) or not (0 <= d < n_devices):
            raise ValueError(f"keep maps lane {i} to device {d}, outside "
                             f"{n_lanes} lanes x {n_devices} devices")
        owner[i] = d
        loads[d] += ests[i]
    M = plan.num_little_lanes
    little = [i for i in range(min(M, n_lanes)) if owner[i] < 0]
    big = [i for i in range(M, n_lanes) if owner[i] < 0]
    # two kind-grouped LPT passes over ONE shared load vector: devices
    # loaded with Little work become preferred targets for Big work, so
    # kinds interleave per device
    for group in (little, big):
        for i in sorted(group, key=lambda i: (-ests[i], i)):
            d = int(np.argmin(loads))
            owner[i] = d
            loads[d] += ests[i]
    return LanePlacement(n_devices=n_devices, num_little_lanes=M,
                         device_of_lane=tuple(owner),
                         lane_ests=tuple(float(e) for e in ests))
