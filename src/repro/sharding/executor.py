"""ShardedExecutor — per-device lane ownership over the layered API.

The shard unit is the packed lane payload (``kernels.ops.pack_lane``):
:func:`~repro.sharding.placement.place_lanes` LPT-assigns lanes to
devices from the perf model's per-lane estimates (Little and Big lanes
interleaved per device), each lane's packed arrays are ``device_put``
to their OWNER device, and one jit'd function per device runs that
device's lanes locally — committed inputs pin execution to the owner,
so dispatching all device fns back-to-back runs them concurrently
(jax dispatch is async). Each device returns its output TILES (and
their global tile indices), and the primary device merges every
device's tiles with ONE tile-indexed scatter-set per iteration per
property, then runs the app's Apply.

Because lanes are globally tile-disjoint, that single scatter-set is a
complete cross-device merge — a psum/pmin/pmax over replicated
per-device accumulators (what the chunk-granular ``core.distributed``
path does inside shard_map) would compute the same values, but would
move ``n_devices × V_pad`` accumulator rows where the tile merge moves
only the real output tiles, and — decisively — it changes the program
shape around Apply: XLA re-fuses a reduce feeding an elementwise chain
differently from a scatter feeding it, which shows up as 1-ULP drift in
'sum' apps. Keeping the merge+apply region STRUCTURALLY IDENTICAL to
the fused single-device iteration (accumulator init → ``merge_all``
scatter-set → Apply) is what makes sharded results bit-identical to it
(tests/test_sharding.py asserts exact equality for all five builtin
apps on both the ref and pallas-interpret kernel paths) — the same
reasoning PR 3 applied to the fused-vs-per-entry pair.

vprops stays replicated (broadcast to every device each iteration; the
property array is the small side — edges dominate and are fully
sharded), mirroring the per-pod-replica serving layout described in
``core.distributed``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.executor import _sub_jaxprs, init_props
from ..core.gas import GASApp, GATHER_IDENTITY
from ..kernels import ops
from .placement import LanePlacement, place_lanes

__all__ = ["ShardedExecutor", "ShardedLanes", "materialize_sharded",
           "resolve_devices"]


def resolve_devices(devices=None) -> tuple:
    """Normalize a ``shard=`` / ``devices=`` argument to a device tuple.

    ``None`` or ``True`` → every local device; an ``int`` n → the first
    n local devices (n must not exceed ``jax.device_count()``); a
    sequence of jax devices → itself, verbatim.
    """
    if devices is None or devices is True:
        return tuple(jax.devices())
    if isinstance(devices, int):
        devs = jax.devices()
        if not (1 <= devices <= len(devs)):
            raise ValueError(
                f"shard={devices} devices requested but only "
                f"{len(devs)} available (hint: on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before "
                f"importing jax)")
        return tuple(devs[:devices])
    devs = tuple(devices)
    if not devs:
        raise ValueError("devices must name at least one device")
    return devs


@dataclasses.dataclass
class ShardedLanes:
    """One plan's lanes materialized onto a fixed device tuple.

    lanes[i] is lane i's packed payload list, RESIDENT on
    ``devices[placement.device_of_lane[i]]``. ``moved``/``bytes_moved``
    account the uploads this materialization performed;
    ``reused``/``bytes_reused`` the lanes carried over resident from a
    pre-delta bundle (streaming) — together they are the
    ``shards_moved`` accounting :func:`repro.streaming.apply_delta`
    surfaces. Memoized on the owning :class:`~repro.core.planner.PlanBundle`
    (one entry per device tuple), so every app executing the plan
    sharded shares one resident copy.
    """

    devices: tuple
    placement: LanePlacement
    lanes: List[List[dict]]
    moved: int = 0
    bytes_moved: int = 0
    reused: int = 0
    bytes_reused: int = 0

    def payloads_of(self, device_idx: int) -> List[dict]:
        """The device's local execution queue: payloads of every lane it
        owns, in lane order (Little lanes first — interleaved kinds)."""
        return [p for i in self.placement.lanes_of(device_idx)
                for p in self.lanes[i]]

    def bytes_per_device(self) -> List[int]:
        out = [0] * self.placement.n_devices
        for i, lane in enumerate(self.lanes):
            out[self.placement.device_of_lane[i]] += sum(
                ops.payload_nbytes(p) for p in lane)
        return out

    def nbytes(self) -> int:
        return sum(self.bytes_per_device())

    def stats(self) -> dict:
        return {
            **self.placement.stats(),
            "lanes_per_device": [
                sum(1 for i in self.placement.lanes_of(d) if self.lanes[i])
                for d in range(self.placement.n_devices)],
            "bytes_per_device": self.bytes_per_device(),
            "shards_moved": self.moved,
            "shard_bytes_moved": self.bytes_moved,
            "shards_reused": self.reused,
            "shard_bytes_reused": self.bytes_reused,
        }


def materialize_sharded(bundle, devices: tuple,
                        keep: Optional[Dict[int, int]] = None,
                        seed: Optional[Dict[int, list]] = None
                        ) -> ShardedLanes:
    """Place a bundle's lanes and upload each to its owner device.

    ``keep`` pins lane→device assignments (streaming: clean lanes stay
    where resident); ``seed`` maps kept lane indices to their resident
    payload lists, which are spliced in without packing or transfer.
    Callers normally go through
    :meth:`repro.core.planner.PlanBundle.sharded_lanes`, which memoizes
    the result per device tuple.
    """
    placement = place_lanes(bundle.plan, len(devices), keep=keep)
    seed = seed or {}
    owners = placement.device_of_lane
    lanes, moved, bytes_moved = ops.pack_lanes_sharded(
        bundle.plan, bundle.little_works, bundle.big_works,
        owners, devices, reuse=seed,
        max_working_set=bundle.config.hw.vmem_lane_budget)
    reused = sum(1 for i, ps in seed.items() if ps)
    bytes_reused = sum(ops.payload_nbytes(p)
                       for ps in seed.values() for p in ps)
    return ShardedLanes(devices=tuple(devices), placement=placement,
                        lanes=lanes, moved=moved, bytes_moved=bytes_moved,
                        reused=reused, bytes_reused=bytes_reused)


class ShardedExecutor:
    """Multi-device counterpart of :class:`~repro.core.executor.Executor`.

    Parameters
    ----------
    store:   the :class:`~repro.core.store.GraphStore` (aux, V_pad, perm).
    bundle:  the cached :class:`~repro.core.planner.PlanBundle` to run.
    app:     the :class:`~repro.core.gas.GASApp`.
    devices: anything :func:`resolve_devices` accepts (None = all local
             devices, int = first n, or an explicit device sequence).
    path:    kernel path ("ref" | "pallas"), as in the Executor.

    Same run/time/stats surface as the Executor (``run`` returns props
    in ORIGINAL vertex ids plus a meta dict; ``time_lanes`` exists only
    on the single-device form). One iteration performs: vprops
    broadcast → per-device local execution (each lane one kernel
    launch, concurrent across devices) → ONE cross-device merge per
    property (a single tile-indexed scatter-set over every device's
    output tiles; ``cross_device_merges`` in :meth:`dispatch_stats`) →
    Apply on the primary device. Results are bit-identical to the
    single-device fused path for every gather mode.
    """

    def __init__(self, store, bundle, app: GASApp, devices=None,
                 path: Optional[str] = None):
        self.store = store
        self.bundle = bundle
        self.app = app
        self.geom = store.geom
        self.path = path or ops.default_path()
        self.V_pad = store.V_pad
        self.devices = resolve_devices(devices)

        t0 = time.perf_counter()
        self.sharded: ShardedLanes = bundle.sharded_lanes(self.devices)
        self.placement = self.sharded.placement
        # per-device local queues (payloads resident on that device)
        self._dev_payloads = [self.sharded.payloads_of(d)
                              for d in range(len(self.devices))]
        self.t_materialize = time.perf_counter() - t0

        self.aux = store.aux
        self._dev_fns = None
        self._merge_apply = None

    @property
    def plan(self):
        return self.bundle.plan

    @property
    def accum_dtype(self):
        return jnp.int32 if self.app.gather == "or" else jnp.float32

    # ------------------------------------------------------------------
    def _build(self) -> None:
        """Build the per-device local fns and the merge+apply fn.

        Each device fn closes over its resident payloads; calling it
        with vprops committed to the same device executes there (no
        implicit transfers — jax refuses mixed-device jit inputs, which
        doubles as an assertion that payloads really are resident). It
        returns the device's concatenated output tiles + global tile
        indices; the merge+apply fn scatter-sets them all at once — the
        same ``merge_all`` + Apply program region the fused
        single-device iteration ends with (bit-identicality; see the
        module docstring)."""
        app, geom = self.app, self.geom
        ident = GATHER_IDENTITY[app.gather]
        dt = self.accum_dtype
        V_pad, path = self.V_pad, self.path

        def make_dev_fn(payloads):
            def local(vprops):
                outs = [ops.run_lane(p, vprops, app.scatter, app.gather,
                                     path) for p in payloads]
                return (jnp.concatenate([o[0] for o in outs]),
                        jnp.concatenate([o[1] for o in outs]))
            return jax.jit(local)

        self._dev_fns = [make_dev_fn(ps) if ps else None
                         for ps in self._dev_payloads]

        def merge_apply(outs, vprops, aux, it):
            accum = jnp.full((V_pad,), ident, dt)
            accum = ops.merge_all(accum, outs, geom.T)
            return app.apply(accum, vprops, aux, it)

        self._merge_apply = jax.jit(merge_apply)

    def _iterate(self, vprops, it):
        """One sharded iteration: broadcast vprops → per-device local
        lanes (concurrent) → pull each device's output tiles to the
        primary → ONE scatter-set merge + Apply there."""
        outs = []
        for d, fn in enumerate(self._dev_fns):
            if fn is None:
                continue
            t, i = fn(jax.device_put(vprops, self.devices[d]))
            outs.append((jax.device_put(t, self.devices[0]),
                         jax.device_put(i, self.devices[0])))
        return self._merge_apply(outs, vprops, self.aux, it)

    def init_props(self):
        return init_props(self.store, self.app)

    def run(self, max_iters: Optional[int] = None, collect_history=False):
        """Run to convergence; returns ``(props, meta)`` with props in
        ORIGINAL vertex ids — the same contract as ``Executor.run``."""
        if self._dev_fns is None:
            self._build()
        vprops = self.init_props()
        iters = max_iters or self.app.max_iters
        history = []
        it_done = 0
        for it in range(iters):
            new = self._iterate(vprops, it)
            new.block_until_ready()
            it_done = it + 1
            if collect_history:
                history.append(np.asarray(new))
            if self.app.converged(vprops, new, it):
                vprops = new
                break
            vprops = new
        out = np.asarray(vprops)[self.store.perm]
        return out, {"iterations": it_done, "history": history}

    def time_iteration(self, repeats: int = 5) -> float:
        """Median wall time of one full sharded iteration (broadcast +
        local lanes + merge + apply)."""
        if self._dev_fns is None:
            self._build()
        vprops = self.init_props()
        self._iterate(vprops, 0).block_until_ready()   # warmup/compile
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            self._iterate(vprops, 0).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    # ------------------------------------------------------------------
    def memory_footprint(self) -> int:
        """Device bytes pinned by the sharded payloads (summed over
        devices; shared with every executor on this bundle+devices —
        attribution for cache budgeting, not exclusive ownership)."""
        return self.sharded.nbytes()

    def merge_trace_stats(self) -> dict:
        """Trace the merge+apply program and count its scatter ops —
        the PROGRAM-DERIVED check that the cross-device merge really is
        one scatter-set per property (:meth:`dispatch_stats` reports
        the static design intent; this can actually fail if a regression
        sneaks extra merges in). Traces fresh on every call — not a hot
        path. Benchmarks/CI gate on ``merge_scatter_ops == 1``."""
        if self._dev_fns is None:
            self._build()
        vprops = self.init_props()
        outs = []
        for d, fn in enumerate(self._dev_fns):
            if fn is None:
                continue
            t, i = fn(jax.device_put(vprops, self.devices[d]))
            outs.append((jax.device_put(t, self.devices[0]),
                         jax.device_put(i, self.devices[0])))
        jaxpr = jax.make_jaxpr(self._merge_apply)(outs, vprops, self.aux,
                                                  0)

        def count_scatters(jx):
            n = sum(1 for e in jx.eqns
                    if e.primitive.name.startswith("scatter"))
            for eqn in jx.eqns:
                for v in eqn.params.values():
                    for sub in _sub_jaxprs(v):
                        n += count_scatters(sub)
            return n

        return {"merge_scatter_ops": count_scatters(jaxpr.jaxpr)}

    def dispatch_stats(self) -> dict:
        """Static launch accounting for one iteration. Kernel launches
        happen per device and run concurrently; the cross-device merge
        is exactly ONE scatter-set per property over all devices'
        output tiles (complete because lanes are tile-disjoint; verify
        against the traced program with :meth:`merge_trace_stats`)."""
        per_dev = [len(ps) for ps in self._dev_payloads]
        return {
            "shard": True,
            "n_devices": len(self.devices),
            "num_entries": sum(p["n_entries"]
                               for ps in self._dev_payloads for p in ps),
            "kernel_dispatches": sum(per_dev),
            "kernel_dispatches_per_device": per_dev,
            "cross_device_merges": 1,
            "payload_bytes": self.memory_footprint(),
        }

    def stats(self) -> dict:
        b, store = self.bundle, self.store
        return {
            "V": store.graph.num_vertices, "E": store.graph.num_edges,
            "partitions": len(b.infos),
            "little_lanes": b.plan.num_little_lanes,
            "big_lanes": b.plan.num_big_lanes,
            "est_makespan": b.plan.est_makespan,
            "placement": self.sharded.stats(),
            **self.dispatch_stats(),
        }
