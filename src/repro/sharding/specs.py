"""Partitioning rules for params, optimizer state, activations and caches.

Strategy (Megatron-style TP on the "model" axis + ZeRO/FSDP-style weight
sharding on the "data" axis for large tensors, batch DP over
("pod","data")):

  * every >=2D weight shards its LAST divisible dim on "model";
  * leaves with >= FSDP_MIN elements additionally shard another divisible
    dim on "data" (GSPMD inserts the per-layer all-gathers);
  * layer-stacked leaves (under "layers"/"enc_layers") never shard dim 0
    — that is the lax.scan axis;
  * non-divisible dims fall back to replication (e.g. qwen2's 12 heads on
    a 16-way model axis);
  * batch-like inputs shard dim 0 over ("pod","data") when divisible,
    then ("data",), else replicate (long_500k's batch=1).

The same rule engine covers optimizer states (their leaves mirror param
shapes or reductions of them), so ZeRO-1 sharding falls out for free.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP_MIN = 1 << 22          # 4M elements: shard weights on "data" too


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _is_stacked(path) -> bool:
    return any(getattr(k, "key", None) in ("layers", "enc_layers")
               for k in path)


def leaf_spec(path, shape, mesh: Mesh) -> P:
    if len(shape) == 0:
        return P()
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")
    lo = 1 if (_is_stacked(path) and len(shape) > 1) else 0
    spec = [None] * len(shape)
    # model axis: last divisible dim
    m_dim = None
    if "model" in mesh.axis_names:
        for d in range(len(shape) - 1, lo - 1, -1):
            if shape[d] % model == 0 and shape[d] >= model:
                spec[d] = "model"
                m_dim = d
                break
    # data axis (FSDP) for big leaves: another divisible dim
    numel = int(np.prod(shape))
    if ("data" in mesh.axis_names and numel >= FSDP_MIN):
        for d in range(len(shape) - 1, lo - 1, -1):
            if d != m_dim and shape[d] % data == 0 and shape[d] >= data:
                spec[d] = "data"
                break
    return P(*spec)


def tree_shardings(tree, mesh: Mesh):
    """NamedSharding pytree for a params/opt-state tree (by shapes)."""
    def f(path, leaf):
        return NamedSharding(mesh, leaf_spec(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(f, tree)


def batch_spec(shape, mesh: Mesh) -> P:
    """Shard dim0 (batch) over ("pod","data") / ("data",) / replicate."""
    cands = []
    if "pod" in mesh.axis_names and "data" in mesh.axis_names:
        cands.append(("pod", "data"))
    if "data" in mesh.axis_names:
        cands.append(("data",))
    for axes in cands:
        size = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if shape[0] % size == 0 and shape[0] >= size:
            return P(axes if len(axes) > 1 else axes[0],
                     *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(tree, mesh: Mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), tree)


def cache_shardings(tree, mesh: Mesh):
    """Decode caches: (L, B, S, KH, hd)-style — shard B (dim1) on data,
    and the head/state dims on model when divisible."""
    model = _axis_size(mesh, "model")
    data = _axis_size(mesh, "data")

    pod = _axis_size(mesh, "pod")

    def f(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2:
            if pod > 1 and leaf.shape[1] % (pod * data) == 0 \
                    and leaf.shape[1] >= pod * data:
                spec[1] = ("pod", "data")
            elif leaf.shape[1] % data == 0 and leaf.shape[1] >= data:
                spec[1] = "data"
        for d in range(len(leaf.shape) - 1, 1, -1):
            if leaf.shape[d] % model == 0 and leaf.shape[d] >= model:
                spec[d] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(f, tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
