"""Sharding: per-device lane ownership with plan-aware placement.

ReGraph scales by giving every lightweight pipeline its own memory
channels; this package applies the same argument one level up — one
DEVICE per lane group, edges fully sharded, the vertex property array
replicated (it is the small side). The shard unit is the packed lane
payload (``kernels.ops.pack_lane``): lanes are tile-disjoint by
construction, so the cross-device merge is a single psum/pmin/pmax-style
reduction per iteration per property.

    placement  — LPT lane→device assignment from the perf model's
                 per-lane estimates (Little/Big interleaved per device),
                 with the greedy balance bound and keep= re-placement
                 for streaming
    executor   — ShardedLanes materialization (device_put to owners,
                 move/reuse accounting) + ShardedExecutor (per-device
                 local execution, one cross-device merge, Apply)
    specs      — off-paper LM-side parameter/activation sharding rules
                 (Megatron/FSDP-style; unrelated to the graph engine)

Entry points: ``api.compile(..., shard=...)``,
``GraphStore.executor(app, shard=...)``, ``GraphStore.shard()``, and
``GraphService.submit(..., shard=...)``. Streaming deltas re-place only
dirty lanes and reuse resident payloads for clean ones
(``shards_moved`` / ``shard_bytes_moved`` in the apply stats).
"""
from .executor import (ShardedExecutor, ShardedLanes, materialize_sharded,
                       resolve_devices)
from .placement import LanePlacement, lane_estimates, place_lanes

__all__ = [
    "LanePlacement", "ShardedExecutor", "ShardedLanes", "lane_estimates",
    "materialize_sharded", "place_lanes", "resolve_devices",
]
