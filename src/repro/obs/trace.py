"""Structured tracing: nested spans, thread-local context, carriers.

Design notes
------------
A :class:`Span` is an interval (epoch start + duration) with a name,
category, attributes, and parent/trace ids. Spans are recorded into
their :class:`Tracer` when **ended**; open spans live only on the
objects holding them, so an abandoned span costs nothing but its own
allocation.

Context propagation is thread-local by default: ``with obs.span(...)``
nests under whatever span the current thread last activated, and costs
one dict lookup (returning a shared no-op) when no tracer is active —
library code (store, planner, executor) can be instrumented
unconditionally. Two boundaries break thread-locality and use explicit
carriers instead:

* the **scheduler queue** hand-off: the submitting thread starts the
  root + queue spans and stores their contexts on the job object; the
  worker thread ends the queue span and ``activate()``-s the root
  context before executing;
* the **process pool**: worker processes build a throwaway local
  ``Tracer``, return ended spans as dicts next to the result, and the
  parent re-parents them under its dispatch span via
  :meth:`Tracer.adopt` (ids are uuid-based, so cross-process spans
  can't collide; starts are ``time.time()`` epoch so clocks line up
  to NTP accuracy).

Timing: ``t_start`` is ``time.time()`` (comparable across processes),
``dur`` is measured with ``perf_counter`` (monotonic, ns resolution).

Export is the Chrome trace-event JSON format (``ph: "X"`` complete
events, microsecond units), loadable in Perfetto / chrome://tracing.
"""
from __future__ import annotations

import json
import threading
import time
import uuid
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "NOOP_SPAN", "Span", "SpanContext", "Tracer", "current",
    "current_ctx", "current_tracer", "span",
]


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


class SpanContext(Tuple[str, str]):
    """Immutable (trace_id, span_id) pair — the wire-safe handle that
    crosses queue/process boundaries instead of a live Span."""
    __slots__ = ()

    def __new__(cls, trace_id: str, span_id: str):
        return tuple.__new__(cls, (trace_id, span_id))

    def __getnewargs__(self):           # pickles across the pool boundary
        return (self[0], self[1])

    @property
    def trace_id(self) -> str:
        return self[0]

    @property
    def span_id(self) -> str:
        return self[1]

    def __repr__(self):  # pragma: no cover - debug aid
        return f"SpanContext(trace_id={self[0]!r}, span_id={self[1]!r})"


class Span:
    """One timed interval. Created by a Tracer; recorded when ended."""

    __slots__ = ("name", "category", "trace_id", "span_id", "parent_id",
                 "t_start", "dur", "attrs", "tid", "_tracer", "_pc0")

    def __init__(self, tracer: "Tracer", name: str, category: str,
                 trace_id: str, parent_id: Optional[str],
                 attrs: Optional[Dict[str, Any]] = None,
                 t_start: Optional[float] = None):
        self.name = name
        self.category = category
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.t_start = time.time() if t_start is None else t_start
        self.dur: Optional[float] = None          # seconds; None = open
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self.tid = threading.get_ident() & 0xFFFFFFFF
        self._tracer = tracer
        # perf_counter anchor for precise durations when t_start was
        # not backdated by the caller
        self._pc0 = time.perf_counter() if t_start is None else None

    @property
    def context(self) -> SpanContext:
        return SpanContext(self.trace_id, self.span_id)

    @property
    def ended(self) -> bool:
        return self.dur is not None

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, t_end: Optional[float] = None, **attrs: Any) -> "Span":
        """End the span (idempotent) and record it into the tracer."""
        if self.dur is not None:
            if attrs:
                self.attrs.update(attrs)
            return self
        if attrs:
            self.attrs.update(attrs)
        if t_end is not None:
            self.dur = max(0.0, t_end - self.t_start)
        elif self._pc0 is not None:
            self.dur = time.perf_counter() - self._pc0
        else:
            self.dur = max(0.0, time.time() - self.t_start)
        self._tracer._record(self)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "cat": self.category,
            "trace_id": self.trace_id, "span_id": self.span_id,
            "parent_id": self.parent_id, "t_start": self.t_start,
            "dur": self.dur, "tid": self.tid, "attrs": dict(self.attrs),
        }


class _NoopSpan:
    """Inert Span stand-in returned when no tracer is active."""
    __slots__ = ()
    ended = True
    context = None
    dur = None
    attrs: Dict[str, Any] = {}

    def set(self, **attrs):
        return self

    def end(self, t_end=None, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()

_local = threading.local()


def current() -> Tuple[Optional["Tracer"], Optional[SpanContext]]:
    """(active tracer, active span context) for this thread."""
    return getattr(_local, "tracer", None), getattr(_local, "ctx", None)


def current_tracer() -> Optional["Tracer"]:
    return getattr(_local, "tracer", None)


def current_ctx() -> Optional[SpanContext]:
    return getattr(_local, "ctx", None)


class _SpanCM:
    """Context manager: opens a child span of the thread-local context
    and makes it the thread-local context for the block."""
    __slots__ = ("_span", "_prev")

    def __init__(self, sp: Span):
        self._span = sp
        self._prev = None

    def __enter__(self) -> Span:
        self._prev = getattr(_local, "ctx", None)
        _local.ctx = self._span.context
        return self._span

    def __exit__(self, exc_type, exc, tb):
        _local.ctx = self._prev
        if exc_type is not None and "error" not in self._span.attrs:
            self._span.attrs["error"] = f"{exc_type.__name__}: {exc}"
        self._span.end()
        return False


def span(name: str, category: str = "", **attrs: Any):
    """Open a child span of this thread's active context.

    Returns a context manager yielding the :class:`Span` (or a shared
    no-op when no tracer is active — safe to call unconditionally from
    library code; the off cost is one attribute lookup).
    """
    tracer = getattr(_local, "tracer", None)
    if tracer is None:
        return NOOP_SPAN
    ctx = getattr(_local, "ctx", None)
    if ctx is None:
        return NOOP_SPAN
    sp = Span(tracer, name, category, ctx.trace_id, ctx.span_id,
              attrs or None)
    return _SpanCM(sp)


class _Activation:
    __slots__ = ("_tracer", "_ctx", "_prev")

    def __init__(self, tracer: "Tracer", ctx: Optional[SpanContext]):
        self._tracer = tracer
        self._ctx = ctx
        self._prev = None

    def __enter__(self):
        self._prev = (getattr(_local, "tracer", None),
                      getattr(_local, "ctx", None))
        _local.tracer = self._tracer
        _local.ctx = self._ctx
        return self._ctx

    def __exit__(self, *exc):
        _local.tracer, _local.ctx = self._prev
        return False


class Tracer:
    """Bounded, thread-safe span sink.

    Ended spans are kept per trace id in an LRU of ``max_traces``
    traces, each capped at ``max_spans_per_trace`` (overflow increments
    a drop counter instead of growing without bound — a tracer wired
    into a long-lived service must never be a leak).

    ``lane_detail`` controls whether the executor switches to the
    per-lane traced execution path (extra dispatches per iteration)
    when this tracer is active; ``False`` keeps coarse spans only.
    """

    def __init__(self, max_traces: int = 256,
                 max_spans_per_trace: int = 4096,
                 lane_detail: bool = True):
        self.max_traces = int(max_traces)
        self.max_spans_per_trace = int(max_spans_per_trace)
        self.lane_detail = bool(lane_detail)
        self._lock = threading.Lock()
        self._traces: "OrderedDict[str, List[Dict[str, Any]]]" = OrderedDict()
        self._dropped = 0
        self._recorded = 0

    # -- span creation -------------------------------------------------
    def start_trace(self, name: str, category: str = "",
                    t_start: Optional[float] = None,
                    **attrs: Any) -> Span:
        """Start a new root span with a fresh trace id."""
        trace_id = uuid.uuid4().hex
        sp = Span(self, name, category, trace_id, None, attrs or None,
                  t_start=t_start)
        with self._lock:
            self._traces[trace_id] = []
            self._traces.move_to_end(trace_id)
            while len(self._traces) > self.max_traces:
                self._traces.popitem(last=False)
        return sp

    def start_span(self, name: str, category: str = "",
                   parent: Optional[SpanContext] = None,
                   t_start: Optional[float] = None,
                   **attrs: Any) -> Span:
        """Start a span under an explicit parent context (carrier use),
        or under the thread-local context when parent is omitted."""
        if parent is None:
            parent = getattr(_local, "ctx", None)
        if parent is None:
            return self.start_trace(name, category, t_start=t_start,
                                    **attrs)
        return Span(self, name, category, parent.trace_id,
                    parent.span_id, attrs or None, t_start=t_start)

    def activate(self, ctx: Optional[SpanContext]) -> _Activation:
        """Bind (self, ctx) as this thread's active tracing context for
        the duration of the ``with`` block."""
        return _Activation(self, ctx)

    # -- recording -----------------------------------------------------
    def _record(self, sp: Span) -> None:
        d = sp.to_dict()
        with self._lock:
            bucket = self._traces.get(sp.trace_id)
            if bucket is None:
                bucket = []
                self._traces[sp.trace_id] = bucket
                while len(self._traces) > self.max_traces:
                    self._traces.popitem(last=False)
            if len(bucket) >= self.max_spans_per_trace:
                self._dropped += 1
                return
            bucket.append(d)
            self._recorded += 1

    def adopt(self, span_dicts: Iterable[Dict[str, Any]],
              parent: SpanContext) -> int:
        """Re-parent spans exported by another tracer (typically a pool
        worker process) under ``parent``: every span's trace_id becomes
        the parent's, and spans that were roots over there (parent_id
        None) hang off the parent span. Returns the adopted count."""
        n = 0
        with self._lock:
            bucket = self._traces.get(parent.trace_id)
            if bucket is None:
                bucket = []
                self._traces[parent.trace_id] = bucket
                while len(self._traces) > self.max_traces:
                    self._traces.popitem(last=False)
            for d in span_dicts:
                if len(bucket) >= self.max_spans_per_trace:
                    self._dropped += 1
                    continue
                d = dict(d)
                d["trace_id"] = parent.trace_id
                if d.get("parent_id") is None:
                    d["parent_id"] = parent.span_id
                bucket.append(d)
                n += 1
            self._recorded += n
        return n

    # -- export --------------------------------------------------------
    def trace_ids(self) -> List[str]:
        with self._lock:
            return list(self._traces)

    def export(self, trace_id: str) -> List[Dict[str, Any]]:
        """Ended spans of one trace, sorted by start time."""
        with self._lock:
            spans = list(self._traces.get(trace_id, ()))
        spans.sort(key=lambda d: d["t_start"])
        return spans

    def to_chrome_trace(self, path: Optional[str] = None,
                        trace_id: Optional[str] = None) -> Dict[str, Any]:
        """Chrome trace-event JSON for one trace (or all traces when
        ``trace_id`` is None). Optionally written to ``path``."""
        with self._lock:
            if trace_id is None:
                spans = [d for b in self._traces.values() for d in b]
            else:
                spans = list(self._traces.get(trace_id, ()))
        spans.sort(key=lambda d: d["t_start"])
        pids = {}
        events = []
        for d in spans:
            pid = pids.setdefault(d["trace_id"], len(pids))
            args = {k: v for k, v in d["attrs"].items()}
            args["span_id"] = d["span_id"]
            if d["parent_id"] is not None:
                args["parent_id"] = d["parent_id"]
            events.append({
                "ph": "X",
                "name": d["name"],
                "cat": d["cat"] or "span",
                "ts": d["t_start"] * 1e6,
                "dur": (d["dur"] or 0.0) * 1e6,
                "pid": pid,
                "tid": d["tid"],
                "args": args,
            })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "traces": len(self._traces),
                "spans_recorded": self._recorded,
                "spans_dropped": self._dropped,
            }
