"""Append-only JSONL perf-regression ledger.

``benchmarks/run.py`` historically overwrote ``BENCH_*.json`` in place,
so the repo accumulated zero perf history — a regression was only
visible if someone happened to diff two CI artifact zips. The ledger
fixes that: every benchmark run APPENDS one record per suite, keyed by

    (git sha, bench name, geometry key, device-spec version)

with the suite's flattened gate metrics, and :meth:`PerfLedger.compare`
flags the latest record's metrics that drifted beyond a tolerance vs
the rolling median of prior records of the same bench. ``run.py
compare`` renders that as a non-blocking CI report step; the ledger
file itself is uploaded as an artifact so the trajectory accumulates
across runs.

Records are plain JSON objects, one per line; readers are tolerant of
corrupt/partial lines (a truncated append must never break the next
run). Regression *direction* uses a name heuristic — metrics that look
like times/latencies/overheads (``*_s``, ``*_ms``, ``p50*``,
``overhead*``, ``ratio*``) are worse when higher, throughputs
(``*gbps*``, ``*teps*``, ``*rate*``) worse when lower; everything else
is reported as neutral "drift".
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional

__all__ = ["PerfLedger", "flatten_metrics", "git_sha"]

DEFAULT_TOLERANCE = 0.25       # |relative change| that flags a metric
DEFAULT_WINDOW = 8             # prior records in the rolling median

_WORSE_HIGHER = ("_s", "_ms", "_us")
_WORSE_HIGHER_SUB = ("p50", "p99", "overhead", "latency", "time",
                     "ratio", "makespan")
_WORSE_LOWER_SUB = ("gbps", "teps", "rate", "throughput", "utilization",
                    "speedup", "efficiency")


def git_sha(cwd: Optional[str] = None) -> str:
    """Best-effort commit id: ``git rev-parse`` → ``REGRAPH_GIT_SHA`` /
    CI-provided ``GITHUB_SHA`` → ``"unknown"``. Never raises."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            cwd=cwd, capture_output=True, text=True, timeout=5)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except Exception:
        pass
    return (os.environ.get("REGRAPH_GIT_SHA")
            or os.environ.get("GITHUB_SHA", "unknown")[:12] or "unknown")


def flatten_metrics(obj: Any, prefix: str = "",
                    max_keys: int = 128) -> Dict[str, float]:
    """Flatten a BENCH_*.json-style document into dotted-key numeric
    leaves (bools excluded; list items indexed). Non-numeric leaves are
    dropped — the ledger stores gate METRICS, not blobs. Bounded to
    ``max_keys`` in first-traversal order so a pathological artifact
    cannot bloat every future compare."""
    out: Dict[str, float] = {}

    def walk(node, pre):
        if len(out) >= max_keys:
            return
        if isinstance(node, bool):
            return
        if isinstance(node, (int, float)):
            out[pre] = float(node)
        elif isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{pre}.{k}" if pre else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{pre}.{i}" if pre else str(i))

    walk(obj, prefix)
    return out


def _direction(name: str) -> str:
    """"higher_is_worse" | "lower_is_worse" | "neutral" by key name."""
    low = name.lower()
    leaf = low.rsplit(".", 1)[-1]
    if any(s in low for s in _WORSE_LOWER_SUB):
        return "lower_is_worse"
    if leaf.endswith(_WORSE_HIGHER) \
            or any(s in low for s in _WORSE_HIGHER_SUB):
        return "higher_is_worse"
    return "neutral"


def _median(xs: List[float]) -> float:
    ys = sorted(xs)
    n = len(ys)
    mid = n // 2
    return ys[mid] if n % 2 else 0.5 * (ys[mid - 1] + ys[mid])


class PerfLedger:
    """Append-only JSONL ledger of benchmark gate metrics."""

    def __init__(self, path: str = "BENCH_ledger.jsonl"):
        self.path = str(path)

    # -- writing --------------------------------------------------------
    def append(self, bench: str, metrics: Dict[str, float], *,
               sha: Optional[str] = None,
               geom_key: Optional[str] = None,
               spec_version: Optional[int] = None,
               meta: Optional[dict] = None) -> dict:
        """Append one record; returns the record dict. The write is a
        single ``write()`` of one line on an append-mode handle, so
        concurrent benches interleave whole lines."""
        rec = {
            "sha": sha if sha is not None else git_sha(),
            "bench": str(bench),
            "geom_key": geom_key,
            "spec_version": (int(spec_version)
                             if spec_version is not None else None),
            "created_at": time.time(),
            "metrics": {str(k): float(v)
                        for k, v in (metrics or {}).items()},
        }
        if meta:
            rec["meta"] = meta
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
        return rec

    # -- reading --------------------------------------------------------
    def records(self, bench: Optional[str] = None) -> List[dict]:
        """All parseable records, file order; corrupt lines skipped."""
        out: List[dict] = []
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except ValueError:
                        continue
                    if not isinstance(rec, dict) or "bench" not in rec:
                        continue
                    if bench is None or rec.get("bench") == bench:
                        out.append(rec)
        except OSError:
            pass
        return out

    def compare(self, bench: Optional[str] = None,
                tolerance: float = DEFAULT_TOLERANCE,
                window: int = DEFAULT_WINDOW) -> dict:
        """Latest record per bench vs the rolling median of up to
        ``window`` prior records of the same bench.

        Returns ``{"benches": {name: {"sha", "n_prior", "flagged":
        [...], "checked": int}}, "regressions": int, "flagged": int}``.
        Each flagged entry carries the metric, latest value, prior
        median, relative change, direction heuristic and whether it
        counts as a regression. Purely a report — callers decide
        whether to fail on it (CI does not)."""
        by_bench: Dict[str, List[dict]] = {}
        for rec in self.records(bench):
            by_bench.setdefault(rec["bench"], []).append(rec)
        report: dict = {"benches": {}, "flagged": 0, "regressions": 0,
                        "tolerance": tolerance}
        for name, recs in sorted(by_bench.items()):
            latest, prior = recs[-1], recs[:-1][-window:]
            entry = {"sha": latest.get("sha"), "n_prior": len(prior),
                     "checked": 0, "flagged": []}
            if prior:
                latest_m = latest.get("metrics") or {}
                for key, val in sorted(latest_m.items()):
                    hist = [r["metrics"][key] for r in prior
                            if isinstance(r.get("metrics"), dict)
                            and isinstance(r["metrics"].get(key),
                                           (int, float))]
                    if not hist:
                        continue
                    entry["checked"] += 1
                    med = _median(hist)
                    denom = max(abs(med), 1e-12)
                    rel = (val - med) / denom
                    if abs(rel) <= tolerance:
                        continue
                    direction = _direction(key)
                    regression = (
                        (direction == "higher_is_worse" and rel > 0)
                        or (direction == "lower_is_worse" and rel < 0))
                    entry["flagged"].append({
                        "metric": key, "value": val, "median": med,
                        "rel_change": rel, "direction": direction,
                        "regression": regression,
                    })
                    report["flagged"] += 1
                    if regression:
                        report["regressions"] += 1
            report["benches"][name] = entry
        return report

    def render_report(self, report: dict) -> str:
        """Human-readable compare report (the CI step's stdout)."""
        lines = [f"perf ledger: {self.path}  "
                 f"(tolerance ±{report['tolerance'] * 100:.0f}% "
                 f"vs rolling median)"]
        for name, entry in report["benches"].items():
            if not entry["n_prior"]:
                lines.append(f"  {name}: first record "
                             f"(sha {entry['sha']}) — no history yet")
                continue
            if not entry["flagged"]:
                lines.append(
                    f"  {name}: ok — {entry['checked']} metrics within "
                    f"tolerance of {entry['n_prior']} prior record(s)")
                continue
            lines.append(f"  {name}: {len(entry['flagged'])} metric(s) "
                         f"beyond tolerance (sha {entry['sha']})")
            for f in entry["flagged"]:
                tag = ("REGRESSION" if f["regression"]
                       else "drift" if f["direction"] == "neutral"
                       else "improvement")
                lines.append(
                    f"    [{tag}] {f['metric']}: {f['value']:.6g} "
                    f"vs median {f['median']:.6g} "
                    f"({f['rel_change'] * 100:+.1f}%)")
        lines.append(f"summary: {report['regressions']} regression(s), "
                     f"{report['flagged']} flagged metric(s)")
        return "\n".join(lines)
