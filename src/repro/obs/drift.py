"""Perf-model drift: measured vs estimated lane times, per kind.

Every traced executor run (and every ``time_lanes`` calibration pass)
produces (pipeline kind, model estimate, measured seconds) samples.
:class:`DriftAccumulator` aggregates them into the drift report that
ROADMAP item 1 (device-spec-calibrated autotuning) needs: if the
``little`` ratio sits at 2.0 while ``big`` sits at 1.1, the model's
Little-pipeline coefficients are what recalibration should move.

Accumulators chain: an Executor-local accumulator forwards samples to
the service-level one (``parent=``), so per-executor detail and the
fleet-wide report come from the same stream.

Report fields per kind (see docs/OBSERVABILITY.md):

``n``              samples seen
``est_s``          total estimated seconds
``measured_s``     total measured seconds
``ratio``          measured_s / est_s  (the headline drift figure)
``ratio_p50``      median of recent per-sample ratios (window)
``ratio_min/max``  extremes over the window
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, Optional

__all__ = ["DriftAccumulator"]


class DriftAccumulator:
    """Thread-safe measured-vs-estimated aggregator keyed by kind.

    Kinds in practice: ``little`` / ``big`` (per-lane samples, lanes
    mixing entry kinds report ``mixed``) and ``makespan`` (whole
    iterations vs the plan's ``est_makespan``).
    """

    def __init__(self, parent: Optional["DriftAccumulator"] = None,
                 window: int = 512):
        self._parent = parent
        self._window = int(window)
        self._lock = threading.Lock()
        self._tot: Dict[str, Dict[str, float]] = {}
        self._recent: Dict[str, deque] = {}

    def set_parent(self, parent: Optional["DriftAccumulator"]) -> None:
        """(Re)chain this accumulator to a parent sink. The autotune
        layer uses this to splice its own clearable accumulator above
        an already-constructed service-level one — executors keep
        chaining to the service accumulator, samples keep flowing up."""
        if parent is self:
            raise ValueError("a DriftAccumulator cannot parent itself")
        self._parent = parent

    def add(self, kind: str, est_s: float, measured_s: float) -> None:
        """Record one sample. Samples with a non-positive estimate are
        counted but excluded from ratio statistics."""
        est_s = float(est_s)
        measured_s = float(measured_s)
        with self._lock:
            tot = self._tot.get(kind)
            if tot is None:
                tot = self._tot[kind] = {"n": 0, "est_s": 0.0,
                                         "measured_s": 0.0}
                self._recent[kind] = deque(maxlen=self._window)
            tot["n"] += 1
            tot["est_s"] += max(0.0, est_s)
            tot["measured_s"] += max(0.0, measured_s)
            if est_s > 0.0:
                self._recent[kind].append(measured_s / est_s)
        if self._parent is not None:
            self._parent.add(kind, est_s, measured_s)

    def report(self) -> Dict[str, Dict[str, Any]]:
        """Per-kind drift summary; empty dict when no samples yet."""
        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            for kind, tot in self._tot.items():
                ratios = sorted(self._recent[kind])
                entry: Dict[str, Any] = {
                    "n": int(tot["n"]),
                    "est_s": tot["est_s"],
                    "measured_s": tot["measured_s"],
                    "ratio": (tot["measured_s"] / tot["est_s"]
                              if tot["est_s"] > 0 else None),
                }
                if ratios:
                    entry["ratio_p50"] = ratios[len(ratios) // 2]
                    entry["ratio_min"] = ratios[0]
                    entry["ratio_max"] = ratios[-1]
                out[kind] = entry
        return out

    def clear(self) -> None:
        with self._lock:
            self._tot.clear()
            self._recent.clear()
