"""Pipeline utilization profiler: analytic lane footprints × measured
lane times → achieved GB/s and %-of-peak.

ReGraph's headline claim is *bandwidth* efficiency — the heterogeneous
Little/Big pipelines exist to keep every HBM channel busy — and the
comparison lens of the FPGA graph-accelerator literature (Dann et al.'s
memory-access-pattern survey, GraphScale) is achieved bandwidth as a
fraction of the device peak. This module closes that gap for the repro:

* :class:`LaneFootprint` — per-lane byte and FLOP accounting derived
  ANALYTICALLY from the packed-lane payloads (``kernels.ops`` already
  knows every array: edge slabs, deduped unique-source tables, merge
  scatter tiles). Two totals matter:

  - ``hbm_bytes``: the traffic model (what the kernel streams/gathers/
    scatters per execution) — the numerator of achieved GB/s;
  - ``total_bytes``: the jaxpr-comparable count (payload arrays +
    the full vprops operand + outputs) — validated against
    :func:`jaxpr_lane_bytes` to ±10% in ``benchmarks/bench_profile.py``.

* :func:`jaxpr_lane_bytes` — an independent byte count from the traced
  jaxpr's constvar/invar/outvar avals; the footprint's ground truth.

* :class:`UtilizationAccumulator` — thread-safe (bytes, flops, seconds)
  aggregator per pipeline kind with per-lane last samples, chained
  executor → service exactly like :class:`~repro.obs.drift.
  DriftAccumulator`, surfaced in ``Executor.stats()["utilization"]``,
  the ``regraph_lane_bandwidth_gbps`` / ``regraph_pipeline_utilization``
  Prometheus gauges, and the control-plane dashboard's per-lane bars.

The %-of-peak denominator is ``HW.peak_bandwidth_gbps`` (calibrated,
persisted through the autotune spec registry) falling back to
``perf_model.effective_peak_bandwidth_bps`` — see docs/OBSERVABILITY.md
for the formulas.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["LaneFootprint", "UtilizationAccumulator", "jaxpr_lane_bytes",
           "lane_footprint", "lane_footprints"]


@dataclasses.dataclass(frozen=True)
class LaneFootprint:
    """Analytic byte/FLOP accounting of one lane's packed payloads.

    Byte classes (summed over the lane's payloads; see
    ``kernels.ops.payload_footprint`` for the per-payload derivation):
    ``edge_bytes`` streamed edge slabs, ``index_bytes`` routing
    metadata, ``table_bytes`` deduped Big compaction tables,
    ``vertex_bytes`` property values actually read (unique sources for
    Big, touched W-windows for Little), ``tile_bytes`` the merge
    scatter traffic, ``vprops_bytes`` the full padded property operand.
    """

    lane: int
    kind: str                  # "little" | "big" | "mixed" | "idle"
    n_payloads: int
    edge_bytes: int
    index_bytes: int
    table_bytes: int
    vertex_bytes: int
    tile_bytes: int
    vprops_bytes: int
    flops: int
    padded_edges: int
    real_edges: int

    @property
    def hbm_bytes(self) -> int:
        """Modelled memory traffic of one lane execution: edge stream +
        routing metadata + gather tables + gathered/streamed vertex
        values + merge scatter tiles. This is the achieved-GB/s
        numerator (the full vprops array is NOT included — only the
        values the kernel touches are)."""
        return (self.edge_bytes + self.index_bytes + self.table_bytes
                + self.vertex_bytes + self.tile_bytes)

    @property
    def total_bytes(self) -> int:
        """Jaxpr-comparable operand+result bytes: every payload array
        (the traced constvars) + the padded vprops operand (the invar)
        + output tiles and scatter indices (the outvars). Gated within
        ±10% of :func:`jaxpr_lane_bytes` in bench_profile."""
        return (self.edge_bytes + self.index_bytes + self.table_bytes
                + self.vprops_bytes + self.tile_bytes)

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (FLOPs per HBM byte) — the roofline
        x-coordinate of this lane."""
        b = self.hbm_bytes
        return self.flops / b if b else 0.0

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["hbm_bytes"] = self.hbm_bytes
        d["total_bytes"] = self.total_bytes
        d["intensity"] = self.intensity
        return d


def lane_footprint(payloads: List[dict], v_pad: int,
                   lane: int = 0) -> Optional["LaneFootprint"]:
    """Build one lane's :class:`LaneFootprint` from its (packed or
    per-entry) payload dicts. Returns None for an empty lane."""
    if not payloads:
        return None
    from ..kernels import ops
    parts = [ops.payload_footprint(p) for p in payloads]
    kinds = {p["kind"] for p in parts}
    kind = kinds.pop() if len(kinds) == 1 else "mixed"
    return LaneFootprint(
        lane=lane,
        kind=kind,
        n_payloads=len(parts),
        edge_bytes=sum(p["edge_bytes"] for p in parts),
        index_bytes=sum(p["index_bytes"] for p in parts),
        table_bytes=sum(p["table_bytes"] for p in parts),
        vertex_bytes=sum(p["vertex_bytes"] for p in parts),
        tile_bytes=sum(p["tile_bytes"] for p in parts),
        vprops_bytes=int(v_pad) * 4,
        flops=sum(p["flops"] for p in parts),
        padded_edges=sum(p["padded_edges"] for p in parts),
        real_edges=sum(p["real_edges"] for p in parts),
    )


def lane_footprints(lanes: List[List[dict]],
                    v_pad: int) -> List[Optional[LaneFootprint]]:
    """Footprints for every lane of an executor's payload structure
    (None entries for fully snapped-away lanes)."""
    return [lane_footprint(lane, v_pad, lane=i)
            for i, lane in enumerate(lanes)]


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for s in shape:
        n *= int(s)
    return n * dtype.itemsize


def jaxpr_lane_bytes(executor, lane_idx: int) -> Optional[int]:
    """Ground-truth byte count of one lane execution, derived from the
    traced jaxpr: the sum of constvar (payload arrays), invar (vprops)
    and outvar (tiles + scatter indices) aval sizes of the same lane fn
    the traced run path jits. Returns None for an empty lane. Traces
    fresh on every call — benchmark/validation use, not a hot path."""
    import jax

    lanes = (executor.packed_lanes if executor.fuse_lanes
             else executor.bundle.lane_entries())
    if lane_idx >= len(lanes) or not lanes[lane_idx]:
        return None
    lane = lanes[lane_idx]

    def lane_fn(vp):
        return [executor._run_payload(p, vp) for p in lane]

    closed = jax.make_jaxpr(lane_fn)(executor.init_props())
    jaxpr = closed.jaxpr
    total = 0
    for v in list(jaxpr.constvars) + list(jaxpr.invars):
        total += _aval_bytes(v)
    for v in jaxpr.outvars:
        total += _aval_bytes(v)
    return total


class UtilizationAccumulator:
    """Thread-safe (bytes, flops, seconds) aggregator per pipeline kind.

    Mirrors :class:`~repro.obs.drift.DriftAccumulator`: executors feed
    per-lane samples (analytic footprint bytes × measured seconds), an
    executor-local accumulator forwards to the service-level one via
    ``parent=``, and :meth:`report` renders the utilization block that
    ``stats()``, the Prometheus gauges and the dashboard read.

    A sample's ``peak_bps`` (the executor's HW-derived bandwidth
    ceiling) rides along so %-of-peak is computed against the spec the
    lane actually ran under, not a global constant.
    """

    # per-lane last-sample retention bound (lanes × kinds is small, but
    # a service-level accumulator sees every executor's lanes)
    _MAX_LANES = 128

    def __init__(self, parent: Optional["UtilizationAccumulator"] = None,
                 window: int = 512):
        self._parent = parent
        self._window = int(window)
        self._lock = threading.Lock()
        self._tot: Dict[str, Dict[str, float]] = {}
        self._recent: Dict[str, deque] = {}
        self._peak: Dict[str, float] = {}       # kind -> last peak_bps
        self._lanes: Dict[int, Dict[str, Any]] = {}

    def set_parent(self,
                   parent: Optional["UtilizationAccumulator"]) -> None:
        if parent is self:
            raise ValueError(
                "a UtilizationAccumulator cannot parent itself")
        self._parent = parent

    def add(self, kind: str, nbytes: float, flops: float,
            measured_s: float, peak_bps: float = 0.0,
            lane: Optional[int] = None) -> None:
        """Record one lane execution: analytic ``nbytes``/``flops``
        moved in ``measured_s`` wall seconds against a ``peak_bps``
        bandwidth ceiling (0 = unknown; utilization reported as None)."""
        nbytes = float(nbytes)
        flops = float(flops)
        measured_s = float(measured_s)
        gbps = (nbytes / measured_s / 1e9) if measured_s > 0 else 0.0
        with self._lock:
            tot = self._tot.get(kind)
            if tot is None:
                tot = self._tot[kind] = {"n": 0, "bytes": 0.0,
                                         "flops": 0.0, "seconds": 0.0}
                self._recent[kind] = deque(maxlen=self._window)
            tot["n"] += 1
            tot["bytes"] += max(0.0, nbytes)
            tot["flops"] += max(0.0, flops)
            tot["seconds"] += max(0.0, measured_s)
            if measured_s > 0:
                self._recent[kind].append(gbps)
            if peak_bps > 0:
                self._peak[kind] = float(peak_bps)
            if lane is not None:
                if (lane not in self._lanes
                        and len(self._lanes) >= self._MAX_LANES):
                    self._lanes.pop(next(iter(self._lanes)))
                self._lanes[lane] = {
                    "kind": kind, "bytes": nbytes, "flops": flops,
                    "measured_s": measured_s, "gbps": gbps,
                    "utilization": (gbps * 1e9 / peak_bps
                                    if peak_bps > 0 else None),
                }
        if self._parent is not None:
            self._parent.add(kind, nbytes, flops, measured_s,
                             peak_bps=peak_bps, lane=lane)

    def report(self) -> Dict[str, Any]:
        """``{"kinds": {kind: {...}}, "lanes": {lane: last sample},
        "peak_bandwidth_gbps": ...}``; empty sub-dicts before the first
        sample. Per-kind fields: n, bytes, seconds, gbps (aggregate
        bytes/seconds), gbps_p50 (median of recent per-sample rates),
        flops_per_s, intensity (flops/byte), utilization (gbps as a
        fraction of the last peak seen, None when no peak known)."""
        out: Dict[str, Any] = {"kinds": {}, "lanes": {}}
        with self._lock:
            peaks = [p for p in self._peak.values() if p > 0]
            out["peak_bandwidth_gbps"] = (max(peaks) / 1e9 if peaks
                                          else None)
            for kind, tot in self._tot.items():
                recent = sorted(self._recent[kind])
                secs = tot["seconds"]
                gbps = tot["bytes"] / secs / 1e9 if secs > 0 else 0.0
                peak = self._peak.get(kind, 0.0)
                entry: Dict[str, Any] = {
                    "n": int(tot["n"]),
                    "bytes": tot["bytes"],
                    "flops": tot["flops"],
                    "seconds": secs,
                    "gbps": gbps,
                    "flops_per_s": (tot["flops"] / secs
                                    if secs > 0 else 0.0),
                    "intensity": (tot["flops"] / tot["bytes"]
                                  if tot["bytes"] > 0 else 0.0),
                    "utilization": (gbps * 1e9 / peak
                                    if peak > 0 else None),
                }
                if recent:
                    entry["gbps_p50"] = recent[len(recent) // 2]
                out["kinds"][kind] = entry
            out["lanes"] = {lane: dict(s)
                            for lane, s in self._lanes.items()}
        return out

    def clear(self) -> None:
        with self._lock:
            self._tot.clear()
            self._recent.clear()
            self._peak.clear()
            self._lanes.clear()
