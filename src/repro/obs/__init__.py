"""Structured observability: tracing spans + perf-model drift.

The serving stack has good *aggregate* metrics (ServiceMetrics
percentiles, Prometheus counters) but aggregates can't answer "where
did THIS request's 900 ms go?". This package adds:

* :mod:`~repro.obs.trace` — a lock-guarded :class:`Tracer` producing
  nested :class:`Span` records with thread-local context propagation,
  explicit carriers across thread/process boundaries, and
  Chrome-trace/Perfetto JSON export.
* :mod:`~repro.obs.drift` — :class:`DriftAccumulator`, aggregating
  measured-vs-model-estimated lane times into the per-pipeline-kind
  drift report that device-spec recalibration (ROADMAP item 1) needs.
* :mod:`~repro.obs.profile` — the pipeline utilization profiler:
  analytic per-lane byte/FLOP footprints (:class:`LaneFootprint`)
  combined with measured lane times into achieved GB/s, arithmetic
  intensity and %-of-peak (:class:`UtilizationAccumulator`).
* :mod:`~repro.obs.ledger` — :class:`PerfLedger`, the append-only
  JSONL perf-regression ledger benchmark runs write and ``run.py
  compare`` reports on.

See docs/OBSERVABILITY.md for the span taxonomy and usage.
"""
from .drift import DriftAccumulator
from .ledger import PerfLedger, flatten_metrics, git_sha
from .profile import (LaneFootprint, UtilizationAccumulator,
                      jaxpr_lane_bytes, lane_footprint, lane_footprints)
from .trace import (NOOP_SPAN, Span, SpanContext, Tracer, current,
                    current_ctx, current_tracer, span)

__all__ = [
    "DriftAccumulator", "LaneFootprint", "NOOP_SPAN", "PerfLedger",
    "Span", "SpanContext", "Tracer", "UtilizationAccumulator",
    "current", "current_ctx", "current_tracer", "flatten_metrics",
    "git_sha", "jaxpr_lane_bytes", "lane_footprint", "lane_footprints",
    "span",
]
