"""Mixed-workload serving demo: one GraphService, three graphs, five
apps, duplicate bursts — showing store/plan/executor cache hits,
coalescing, and the per-request latency breakdown.

    PYTHONPATH=src python examples/serving.py
"""
import numpy as np

from repro import api
from repro.graphs.rmat import rmat

GEOM = api.Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)
APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
    ("closeness", {"sources": np.arange(4)}),
]

graphs = [rmat(10, 8, seed=s, weighted=True) for s in (1, 2, 3)]

with api.GraphService(workers=2, default_geom=GEOM,
                      byte_budget=1 << 30) as svc:
    # register up front so even the first request only pays planning
    fps = [svc.register(g) for g in graphs]

    for label in ("cold", "warm"):
        handles = [svc.submit(fingerprint=fp, app=name, app_kwargs=kw,
                              n_lanes=4, max_iters=5)
                   for fp in fps for name, kw in APPS]
        results = [h.result(timeout=600) for h in handles]
        lat = sorted(h.metrics.t_total_ms for h in handles)
        print(f"{label:4s}: {len(handles)} requests, "
              f"p50={lat[len(lat) // 2]:.1f} ms p99={lat[-1]:.1f} ms")

    # 16 concurrent identical requests -> one execution, fanned out
    before = svc.metrics.executions
    burst = [svc.submit(fingerprint=fps[0], app="pagerank", n_lanes=4,
                        max_iters=5) for _ in range(16)]
    for h in burst:
        h.result(timeout=600)
    print(f"coalescing: 16 submits -> "
          f"{svc.metrics.executions - before} execution(s)")

    h = burst[0]
    print(f"breakdown of request {h.request_id}: "
          f"queue={h.metrics.t_queue_ms:.1f} ms "
          f"store={h.metrics.t_store_ms:.1f} ms "
          f"plan={h.metrics.t_plan_ms:.1f} ms "
          f"execute={h.metrics.t_execute_ms:.1f} ms "
          f"(store_hit={h.metrics.store_hit} plan_hit={h.metrics.plan_hit})")

    snap = svc.stats()
    print(f"store cache: {snap['store_cache']['stores']} stores, "
          f"{snap['store_cache']['current_bytes'] / 1e6:.1f} MB, "
          f"hit rate {snap['service']['store_hit_rate']:.0%}; "
          f"{snap['cached_executors']} cached executors")
