"""Sharded execution demo: per-device lane ownership with plan-aware
placement, compared bit-for-bit against the single-device fused path,
then a streaming delta showing resident shard payloads being reused.

Multi-device: uses every device ``jax.device_count()`` reports. On a
CPU-only host the script re-executes itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the demo runs
on 8 (forced) devices; on real multi-chip hardware it uses the chips
as-is.

    PYTHONPATH=src python examples/sharding.py
"""
import os
import sys

if ("--no-reexec" not in sys.argv
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    # force a multi-device topology BEFORE jax is imported (device
    # count is fixed at import time); real TPU/GPU hosts can pass
    # --no-reexec to use the hardware devices directly
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")
    os.execv(sys.executable, [sys.executable] + sys.argv + ["--no-reexec"])

import jax             # noqa: E402
import numpy as np     # noqa: E402

from repro import api                                    # noqa: E402
from repro.graphs.rmat import rmat                       # noqa: E402
from repro.streaming import random_delta, apply_delta    # noqa: E402

N_DEV = jax.device_count()
GEOM = api.Geometry(U=256, W=256, T=256, E_BLK=256, big_batch=4)

graph = rmat(13, 12, seed=42, weighted=True)
store = api.GraphStore(graph, geom=GEOM)
cfg = api.PlanConfig(n_lanes=N_DEV)
print(f"graph: V={graph.num_vertices} E={graph.num_edges}  "
      f"devices: {N_DEV}")

# -- shard the plan's lanes across devices ------------------------------
sharded = store.shard(cfg)             # LPT placement + device_put
print("placement:", {k: sharded.stats()[k] for k in
                     ("lanes_per_device", "bytes_per_device",
                      "imbalance")})

# -- run sharded, verify bit-identical vs the single-device fused path --
for app in ("pagerank", "sssp", "wcc"):
    single = api.compile(None, app, store=store, config=cfg, path="ref")
    multi = api.compile(None, app, store=store, config=cfg, path="ref",
                        shard=True)
    p1, m1 = single.run(max_iters=8)
    p2, m2 = multi.run(max_iters=8)
    assert m1["iterations"] == m2["iterations"]
    np.testing.assert_array_equal(p1, p2)
    d = multi.executor.dispatch_stats()
    print(f"{app:9s} OK  iters={m2['iterations']}  "
          f"dispatches/device={d['kernel_dispatches_per_device']}  "
          f"cross-device merges={d['cross_device_merges']}")

# -- streaming: a skewed delta re-places only dirty lanes ---------------
delta = random_delta(graph, churn=0.01, hot_frac=0.01,
                     base_fp=store.fingerprint())
res = apply_delta(store, delta)
s = res.stats
print(f"delta: {s['dirty_partitions']}/{s['partitions']} partitions "
      f"dirty; shards moved={s['shards_moved']} "
      f"({s['shard_bytes_moved']} B), reused resident="
      f"{s['shards_reused']} ({s['shard_bytes_reused']} B)")

p3, _ = api.compile(None, "pagerank", store=res.store, config=cfg,
                    path="ref", shard=True).run(max_iters=8)
p4, _ = api.compile(None, "pagerank", store=res.store, config=cfg,
                    path="ref").run(max_iters=8)
np.testing.assert_array_equal(p3, p4)
print("post-delta sharded run OK (bit-identical to single-device)")
print("store stats placement:", store.stats()["placement"])
