"""Control-plane demo: a GraphService with the process-pool worker
tier, multi-tenant admission, priority/deadline scheduling, and the
HTTP job API — submit over HTTP, watch a job run to completion, stream
an update, read Prometheus metrics, and dump the job's end-to-end
trace (open ``trace.json`` at https://ui.perfetto.dev).

    PYTHONPATH=src python examples/control_plane.py
"""
import json
import time
import urllib.request

from repro import api
from repro.graphs.rmat import rmat
from repro.streaming import random_delta

GEOM = api.Geometry(U=1024, W=512, T=512, E_BLK=128, big_batch=4)


def http(method, url, body=None):
    req = urllib.request.Request(
        url, method=method,
        data=None if body is None else json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# the __main__ guard is REQUIRED: pool workers start via spawn, which
# re-imports this file in each child
def main():
    g = rmat(10, 8, seed=7, weighted=True)

    # pool=2: store builds + delta splices run in worker PROCESSES
    # (worker 0 is the dedicated apply lane), keeping the serving
    # interpreter free. Quotas: each tenant gets a 4-job burst
    # refilling at 2 jobs/s.
    with api.GraphService(workers=2, default_geom=GEOM,
                          default_path="ref", pool=2,
                          default_quota=api.TenantQuota(rate=2.0,
                                                        burst=4)
                          ) as svc:
        # prepare=False: the store builds inside the first job (in a
        # pool worker), so its trace shows the whole cold path
        fp = svc.register(g, prepare=False)
        plane = api.ControlPlane(svc)
        server, base = api.serve_jobs(plane)
        print(f"job API listening on {base}")

        # -- submit over HTTP, poll to completion -------------------------
        code, job = http("POST", f"{base}/jobs", {
            "fingerprint": fp, "app": "pagerank", "max_iters": 10,
            "tenant": "alice", "priority": 5, "n_lanes": 4,
        })
        jid = job["id"]
        print(f"POST /jobs -> {code} id={jid} state={job['state']}")
        while True:
            _, job = http("GET", f"{base}/jobs/{jid}")
            if job["terminal"]:
                break
            time.sleep(0.05)
        _, res = http("GET", f"{base}/jobs/{jid}/result")
        print(f"GET /jobs/{jid[:8]}… -> {job['state']} in "
              f"{job['metrics']['t_total_ms']:.0f} ms, "
              f"{res['num_properties']} properties")

        # -- a streaming update through the apply lane --------------------
        delta = random_delta(g, churn=0.01, seed=1, hot_frac=0.01)
        upd = plane.update_job(fp, delta, tenant="alice").metrics
        print(f"update: {upd['mode']} path "
              f"in {upd['t_update_ms']:.1f} ms -> "
              f"new fingerprint {upd['fingerprint'][:12]}…")

        # -- admission control: burst past bob's quota --------------------
        codes = []
        for i in range(8):
            # distinct max_iters so the burst can't coalesce into one
            # job (coalesced duplicates bypass admission by design)
            code, _ = http("POST", f"{base}/jobs", {
                "fingerprint": upd["fingerprint"],
                "app": "wcc", "max_iters": i + 1, "tenant": "bob"})
            codes.append(code)
        print(f"bob's burst of 8: {codes.count(201)} admitted, "
              f"{codes.count(429)} rejected (429 quota)")
        for _ in range(100):                      # let admitted jobs drain
            _, jobs = http("GET", f"{base}/jobs?tenant=bob")
            if all(j["terminal"] for j in jobs["jobs"]):
                break
            time.sleep(0.1)

        # -- metrics ------------------------------------------------------
        with urllib.request.urlopen(f"{base}/metrics", timeout=30) as r:
            prom = r.read().decode()
        wanted = ("regraph_requests_total", "regraph_rejected_total",
                  "regraph_pool_jobs_total", "regraph_updates_total")
        print("GET /metrics (excerpt):")
        for line in prom.splitlines():
            if line.startswith(wanted):
                print(f"  {line}")

        # -- the first job's end-to-end trace -----------------------------
        # every span of its path — HTTP submit, queue wait, pool-worker
        # store build, plan, per-lane execution, merge/apply — in Chrome
        # trace-event JSON (chrome://tracing or ui.perfetto.dev)
        _, trace = http("GET", f"{base}/jobs/{jid}/trace")
        with open("trace.json", "w") as f:
            json.dump(trace, f, indent=1)
        events = trace["traceEvents"]
        print(f"GET /jobs/{jid[:8]}…/trace -> {len(events)} spans "
              f"-> trace.json")
        print("top-3 slowest spans:")
        for ev in sorted(events, key=lambda e: -e["dur"])[:3]:
            print(f"  {ev['dur'] / 1e3:8.1f} ms  {ev['name']}"
                  + (f"  (lane {ev['args']['lane']},"
                     f" {ev['args']['kind']})"
                     if ev["name"] == "executor.lane" else ""))

        server.shutdown()


if __name__ == "__main__":
    main()
