"""Evolving-graph serving demo: one GraphService, an RMAT graph under
continuous degree-skewed edge churn, delta updates racing concurrent
submits. Each round submits the app mix against the current snapshot,
applies a churn delta through ``GraphService.update`` while those
requests are in flight, then queries the NEW snapshot — showing
incremental apply latency, dirty-partition counts, packed-payload
carry-over, and warm-hit/invalidation stats.

    PYTHONPATH=src python examples/streaming.py
"""
import numpy as np

from repro import api
from repro.graphs.rmat import rmat
from repro.streaming import apply_delta_to_graph, random_delta

GEOM = api.Geometry(U=512, W=256, T=256, E_BLK=256, big_batch=4)
APPS = [
    ("pagerank", {}),
    ("bfs", {"root": 0}),
    ("sssp", {"root": 0}),
    ("wcc", {}),
]
ROUNDS = 4
CHURN = 0.005           # 0.5% of edges per round
HOT_FRAC = 0.01         # churn concentrates on hot vertices (the
                        # preferential-attachment pattern DBG localizes)

graph = rmat(13, 12, seed=42, weighted=True)

with api.GraphService(workers=2, default_geom=GEOM,
                      default_path="ref") as svc:
    fp = svc.register(graph)
    print(f"base: V={graph.num_vertices} E={graph.num_edges} "
          f"fp={fp[:12]}…")

    for rnd in range(ROUNDS):
        # submits against the CURRENT snapshot ...
        handles = [svc.submit(fingerprint=fp, app=name, app_kwargs=kw,
                              n_lanes=8, max_iters=4)
                   for name, kw in APPS]
        # ... race a delta update; in-flight requests finish on the old
        # snapshot (lease-pinned), the cache re-keys to the new one
        delta = random_delta(graph, churn=CHURN, seed=100 + rnd,
                             hot_frac=HOT_FRAC, base_fp=fp)
        res = svc.update(fp, delta)
        s = res.stats
        print(f"round {rnd}: update {res.t_update_ms:6.1f} ms "
              f"({delta.num_changes} changes, "
              f"dirty {s['dirty_partitions']}/{s['partitions']} parts, "
              f"packed lanes reused {s['packed_lanes_reused']}, "
              f"repacked {s['packed_lanes_repacked']}, "
              f"old store retired: {res.retired})")

        for (name, _), h in zip(APPS, handles):
            h.result(timeout=300)       # old-snapshot requests complete

        # the generator tracks the evolving graph for the next delta
        # (the service itself only needs the chain)
        graph = apply_delta_to_graph(graph, delta, check_fp=False)
        fp = res.fingerprint

        # post-update queries land warm on the spliced store
        h = svc.submit(fingerprint=fp, app="pagerank", n_lanes=8,
                       max_iters=4)
        _, meta = h.result(timeout=300)
        print(f"         post-update pagerank: "
              f"store_hit={h.metrics.store_hit} "
              f"plan_hit={h.metrics.plan_hit} "
              f"total={h.metrics.t_total_ms:.1f} ms")

    snap = svc.metrics.snapshot()
    print(f"\nservice: {snap['completed']} requests, "
          f"{snap['updates']} updates "
          f"(p50 {snap['p50_update_ms']:.1f} ms), "
          f"{snap['stores_retired']} snapshots retired, "
          f"{snap['plans_rebuilt']} plans rebuilt, "
          f"packed lanes reused/repacked "
          f"{snap['packed_lanes_reused']}/{snap['packed_lanes_repacked']}, "
          f"store hit rate {snap['store_hit_rate']:.0%}")
    cache = svc.cache.stats()
    print(f"store cache: {cache['stores']} live stores, "
          f"{cache['evictions']} evictions, "
          f"{cache['freed_plan_bytes'] / 1e6:.1f} MB of plan payloads "
          f"freed by retirement")

    # sanity: the final served snapshot matches a direct build of the
    # final graph (BFS is order-exact)
    served, _ = svc.run(fingerprint=fp, app="bfs", app_kwargs={"root": 0},
                        n_lanes=8, max_iters=6, timeout=300)
    direct, _ = api.compile(graph, "bfs", geom=GEOM, n_lanes=8,
                            path="ref").run(max_iters=6)
    assert np.array_equal(served, direct)
    print("final snapshot verified against a direct rebuild ✓")
