"""Serve a small model with batched requests through the wave engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine

cfg = reduced(get_config("internlm2_1p8b"))
model = build_model(cfg)
params = model.init(jax.random.key(0))
engine = ServeEngine(model, params, max_batch=4, max_seq=96)

rng = np.random.default_rng(0)
requests = [
    Request(tokens=rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32),
            max_new_tokens=12)
    for n in rng.integers(8, 32, 10)
]
stats = engine.serve(requests)
print("generated (first 3 requests):")
for r in requests[:3]:
    print("  ", r.out.tolist())
print({k: round(v, 3) if isinstance(v, float) else v
       for k, v in stats.items()})
