"""All five GAS applications on three datasets via the layered API —
the ReGraph §V push-button flow with preprocessing amortized: one
GraphStore per dataset, five apps planned and run from it.

    PYTHONPATH=src python examples/graph_apps.py
"""
import time

import numpy as np

from repro import api
from repro.graphs import datasets

GEOM = api.Geometry(U=2048, W=512, T=512, E_BLK=256, big_batch=8)
CONFIG = api.PlanConfig(n_lanes=8)

APP_MAKERS = (api.make_pagerank, lambda: api.make_bfs(root=0),
              lambda: api.make_sssp(root=0), api.make_wcc,
              api.make_closeness)

for name in ("ggs", "g17s", "tcs"):
    g = datasets.load(name)
    if g.weights is None:
        # attach deterministic weights so SSSP shares the same store
        g.weights = np.random.RandomState(42).uniform(
            0.1, 1.0, g.num_edges).astype(np.float32)

    t0 = time.perf_counter()
    store = api.GraphStore(g, geom=GEOM)
    bundle = store.plan(CONFIG)          # blocking + scheduling, ONCE
    t_prep = time.perf_counter() - t0
    print(f"\n=== {name}: V={g.num_vertices} E={g.num_edges} "
          f"({datasets.info(name)['paper']}) ===")
    print(f"  preprocessing once: {t_prep*1e3:.1f} ms "
          f"(blocking {bundle.t_block*1e3:.1f} ms, "
          f"scheduling {bundle.t_plan*1e3:.2f} ms) → "
          f"plan {bundle.plan.num_little_lanes}L"
          f"{bundle.plan.num_big_lanes}B "
          f"dense={len(bundle.dense)} sparse={len(bundle.sparse)}")

    for mk in APP_MAKERS:
        app = mk()
        props, meta = store.plan_and_run(app, CONFIG)  # plan cache hit
        print(f"  {app.name:10s} iters={meta['iterations']:3d}")

    st = store.stats()
    print(f"  amortized: {st['cached_little_works']} little + "
          f"{st['cached_big_works']} big blockings and "
          f"{st['cached_plans']} plan shared by {len(APP_MAKERS)} apps")
