"""All five GAS applications on three datasets, with the model-guided
scheduling plan printed for each — the ReGraph §V push-button flow.

    PYTHONPATH=src python examples/graph_apps.py
"""
import numpy as np

from repro.core import gas
from repro.core.engine import HeterogeneousEngine
from repro.core.types import Geometry
from repro.graphs import datasets

GEOM = Geometry(U=2048, W=512, T=512, E_BLK=256, big_batch=8)

for name in ("ggs", "g17s", "tcs"):
    g = datasets.load(name)
    print(f"\n=== {name}: V={g.num_vertices} E={g.num_edges} "
          f"({datasets.info(name)['paper']}) ===")
    for mk in (gas.make_pagerank, lambda: gas.make_bfs(root=0),
               lambda: gas.make_sssp(root=0), gas.make_wcc,
               gas.make_closeness):
        app = mk()
        if app.needs_weights:
            from repro.graphs.rmat import rmat
            g2 = rmat(12, 8, seed=42, weighted=True)
        else:
            g2 = g
        eng = HeterogeneousEngine(g2, app, geom=GEOM, n_lanes=8)
        props, meta = eng.run()
        s = eng.stats()
        print(f"  {app.name:10s} iters={meta['iterations']:3d} "
              f"plan={s['little_lanes']}L{s['big_lanes']}B "
              f"dense={s['dense']} sparse={s['sparse']}")
