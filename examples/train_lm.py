"""End-to-end driver: train a ~15M-param qwen2-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models.api import build_model
from repro.optim.adamw import adamw
from repro.optim.schedule import warmup_cosine
from repro.train.loop import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--arch", default="qwen2_1p5b")
args = ap.parse_args()

import dataclasses
cfg = dataclasses.replace(reduced(get_config(args.arch), layers=4),
                          d_model=128, d_ff=512)
model = build_model(cfg)
n_params = sum(p.size for p in jax.tree.leaves(
    jax.eval_shape(model.init, jax.random.key(0))))
print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M")

trainer = Trainer(
    model,
    adamw(lr=warmup_cosine(peak=1e-3, warmup=30, total=args.steps)),
    DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=16),
    run_dir="runs/train_lm",
    checkpoint_every=100,
)
params, _, losses = trainer.run(args.steps, log_every=25)
print(f"loss: {losses[:10].mean():.3f} (first 10) -> "
      f"{losses[-10:].mean():.3f} (last 10)")
assert losses[-10:].mean() < losses[:10].mean(), "loss must decrease"
print("done — checkpoints in runs/train_lm/ckpt (restart resumes exactly)")
