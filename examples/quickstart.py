"""Quickstart: PageRank on an R-MAT graph with the layered API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.graphs.rmat import rmat

graph = rmat(scale=12, edge_factor=16, seed=7)
print(f"graph: {graph.num_vertices} vertices, {graph.num_edges} edges")

compiled = api.compile(
    graph, api.make_pagerank(max_iters=20),
    geom=api.Geometry(U=2048, W=512, T=512, E_BLK=256, big_batch=8),
    n_lanes=8,
)
print("schedule:", {k: v for k, v in compiled.stats().items()
                    if not k.startswith("t_")})

props, meta = compiled.run()
rank = props[:graph.num_vertices] * np.maximum(graph.out_degrees(), 1)
top = np.argsort(-rank)[:5]
print(f"converged in {meta['iterations']} iterations")
print("top-5 vertices by PageRank:", list(zip(top.tolist(),
                                              np.round(rank[top], 6))))
it = compiled.time_iteration()
print(f"one iteration: {it*1e3:.1f} ms "
      f"({graph.num_edges/it/1e6:.0f} MTEPS on this host)")

# the store is reusable: plan a second app without re-preprocessing
props_bfs, meta_bfs = compiled.store.plan_and_run(api.make_bfs(root=0))
reached = int((props_bfs[:graph.num_vertices] < 3.0e38).sum())
print(f"BFS from the same store: {reached} vertices reached "
      f"in {meta_bfs['iterations']} iterations "
      f"(cached plans: {compiled.store.stats()['cached_plans']})")
