"""Quickstart: PageRank on an R-MAT graph with the heterogeneous engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import gas
from repro.core.engine import HeterogeneousEngine
from repro.core.types import Geometry
from repro.graphs.rmat import rmat

graph = rmat(scale=12, edge_factor=16, seed=7)
print(f"graph: {graph.num_vertices} vertices, {graph.num_edges} edges")

app = gas.make_pagerank(max_iters=20)
engine = HeterogeneousEngine(
    graph, app,
    geom=Geometry(U=2048, W=512, T=512, E_BLK=256, big_batch=8),
    n_lanes=8,
)
print("schedule:", {k: v for k, v in engine.stats().items()
                    if k not in ("t_dbg_ms", "t_partition_schedule_ms")})

props, meta = engine.run()
rank = props[:graph.num_vertices] * np.maximum(graph.out_degrees(), 1)
top = np.argsort(-rank)[:5]
print(f"converged in {meta['iterations']} iterations")
print("top-5 vertices by PageRank:", list(zip(top.tolist(),
                                              np.round(rank[top], 6))))
it = engine.time_iteration()
print(f"one iteration: {it*1e3:.1f} ms "
      f"({graph.num_edges/it/1e6:.0f} MTEPS on this host)")
